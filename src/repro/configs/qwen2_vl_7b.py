"""qwen2-vl-7b [vlm]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.

M-RoPE with sections (16, 24, 24) over head_dim=128; dynamic-resolution
vision frontend is a STUB per the assignment — ``input_specs()`` provides
1024 precomputed patch embeddings per sample plus explicit 3-channel (t/h/w)
positions; text tokens fill the rest of the sequence.  [arXiv:2409.12191; hf]
"""

from .base import LayerSpec, ModelConfig, uniform_program

_SPEC = LayerSpec(attn="full", ffn="dense")


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b",
        family="vlm",
        num_layers=28,
        d_model=3584,
        num_heads=28,
        num_kv_heads=4,
        head_dim=128,
        d_ff=18944,
        vocab_size=152_064,
        program=uniform_program(_SPEC, 28),
        mrope_sections=(16, 24, 24),
        rope_theta=1_000_000.0,
        frontend="vision_stub",
        num_patch_tokens=1024,
        tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-smoke",
        family="vlm",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        program=uniform_program(_SPEC, 3),
        mrope_sections=(2, 3, 3),
        frontend="vision_stub",
        num_patch_tokens=8,
        dtype="float32",
    )
