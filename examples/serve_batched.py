"""Batched serving across architecture families: prefill + KV-cache decode.

    PYTHONPATH=src python examples/serve_batched.py

Serves three different cache disciplines side by side on smoke-scale models:
  * qwen3  — full KV cache (GQA),
  * gemma3 — sliding-window ring caches (5 local : 1 global),
  * mamba2 — constant recurrent state (the long_500k discipline).
Prints per-family decode throughput and shows the generations are
deterministic for identical prompts.
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import build_model


def serve(arch: str, batch: int = 4, prompt_len: int = 24, gen: int = 12):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (batch, prompt_len), 0,
                              cfg.vocab_size, jnp.int32)
    max_seq = prompt_len + gen
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, max_seq=max_seq))(
        params, {"tokens": toks}
    )
    nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    decode = jax.jit(model.decode_step, donate_argnums=(1,))
    out = [nxt]
    t0 = time.time()
    for i in range(gen - 1):
        logits, cache = decode(params, cache, nxt, jnp.asarray(prompt_len + i, jnp.int32))
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        out.append(nxt)
    dt = time.time() - t0
    seq = jnp.concatenate(out, axis=1)
    print(f"{arch:12s} {batch} seqs x {gen} tokens  "
          f"{batch*(gen-1)/max(dt,1e-9):7.1f} tok/s   sample={seq[0,:8].tolist()}")
    return seq


def main():
    for arch in ("qwen3-4b", "gemma3-4b", "mamba2-370m"):
        a = serve(arch)
        b = serve(arch)
        assert (a == b).all(), "serving must be deterministic"
    print("deterministic across repeats: OK")


if __name__ == "__main__":
    main()
