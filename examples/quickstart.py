"""Quickstart: the paper's memory planner on a real training step, end to end.

    PYTHONPATH=src python examples/quickstart.py

1. builds a small qwen3-family model,
2. runs the repro.plan pipeline on its train step: TraceCapture extracts the
   step's variable lifetimes (model-transparently, via jaxpr) into a
   MemoryProgram, PoolPlacement runs SmartPool (offline DSA) against the
   CnMem-style online pool and the exact allocator — the paper's Table I
   quantities,
3. runs AutoSwap scorers from the strategy registry to find the largest
   zero-overhead memory-load reduction — the paper's Table II quantity,
4. persists the solved plan to an on-disk artifact and reloads it, showing
   the solve-once/reuse-forever contract (paper §V),
5. trains a few steps to show nothing about the model changed.
"""

import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.configs.base import LayerSpec
from repro.core import TPU_V5E
from repro.core.planner import MemoryPlanner
from repro.models import build_model
from repro.optim import adamw_init
from repro.launch.steps import build_train_step
from repro.plan import PlanCache, PlanKey, scorer_names


def main():
    cfg = get_smoke_config("qwen3-4b").reduced(
        name="quickstart", num_layers=4, d_model=256, num_heads=8, num_kv_heads=4,
        head_dim=32, d_ff=1024, vocab_size=8192,
        program=(((LayerSpec(attn="full", ffn="dense"),), 4),),
    )
    model = build_model(cfg)
    B, S = 8, 256
    batch = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    pshapes = model.init_shapes()

    def step(params, batch):
        return model.loss(params, batch)[0]

    print("== planning (model-transparent, from the jaxpr) ==")
    with tempfile.TemporaryDirectory() as plan_dir:
        cache = PlanCache(plan_dir)
        key = PlanKey("quickstart", f"train:b{B}s{S}", TPU_V5E.name)
        planner = MemoryPlanner(step, pshapes, batch, hw=TPU_V5E, cache=cache, key=key)
        rep = planner.report()
        print(f" variables            : {rep.num_variables}")
        print(f" peak load omega(G)   : {rep.peak_load/2**20:8.2f} MiB")
        print(f" SmartPool chi(G)     : {rep.smartpool_footprint/2**20:8.2f} MiB "
              f"(ratio {rep.smartpool_ratio:.4f})")
        print(f" CnMem-style pool     : {rep.cnmem_footprint/2**20:8.2f} MiB "
              f"(ratio {rep.cnmem_ratio:.4f})")

        print("\n== AutoSwap: zero-overhead reduction per priority score ==")
        for m in (s for s in scorer_names() if s != "bo"):
            limit, ov = planner.swap.max_zero_overhead_reduction(method=m, grid=16)
            red = 100 * (1 - limit / max(planner.swap.peak_load, 1))
            print(f"  {m:6s}: load -> {limit/2**20:8.2f} MiB  (-{red:.1f}%), overhead {ov*100:.2f}%")

        print("\n== solve once, reuse forever: reload the plan artifact ==")
        reloaded = MemoryPlanner(None, cache=cache, key=key)  # no step_fn: no re-trace
        rep2 = reloaded.report()
        assert rep2.as_dict() == rep.as_dict()
        print(f" artifact {cache.keys()[0]}.json restored "
              f"(from_cache={reloaded.from_cache}), reports identical")

    print("\n== training (unchanged numerics) ==")
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    train = jax.jit(build_train_step(model, cfg), donate_argnums=(0, 1))
    from repro.data import SyntheticTokens

    ds = SyntheticTokens(cfg.vocab_size, S, B)
    for i in range(5):
        b = {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}
        params, opt, metrics = train(params, opt, b, jnp.asarray(i, jnp.int32))
        print(f"  step {i}  loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
