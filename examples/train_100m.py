"""End-to-end driver: train a ~100M-parameter qwen3-family model.

    PYTHONPATH=src python examples/train_100m.py [--steps 200]

Exercises the full stack on whatever devices exist: config -> model ->
sharded synthetic data -> AdamW + warmup-cosine -> checkpoint/resume ->
memory planner report.  On a TPU slice the same script runs unmodified with
the production mesh (the step function is the one the dry-run lowers).
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import LayerSpec, ModelConfig, uniform_program
from repro.data import Prefetcher, SyntheticTokens
from repro.models import build_model
from repro.optim import adamw_init, linear_warmup_cosine
from repro.launch.steps import build_train_step


def config_100m() -> ModelConfig:
    # ~97M params: 10L x d640 x ff2560, vocab 50k (tied embeddings)
    return ModelConfig(
        name="qwen3-100m",
        family="dense",
        num_layers=10,
        d_model=640,
        num_heads=10,
        num_kv_heads=5,
        head_dim=64,
        d_ff=2560,
        vocab_size=50_000,
        program=uniform_program(LayerSpec(attn="full", ffn="dense"), 10),
        qk_norm=True,
        rope_theta=10_000.0,
        dtype="float32",
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/ckpt_100m")
    args = ap.parse_args(argv)

    cfg = config_100m()
    model = build_model(cfg)
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(model.init_shapes()))
    print(f"model: {cfg.name}  params={n/1e6:.1f}M")

    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    lr = linear_warmup_cosine(3e-4, 20, args.steps)

    def step_fn(params, opt_state, batch, step):
        fn = build_train_step(model, cfg, lr=3e-4)
        return fn(params, opt_state, batch, step)

    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
    ds = SyntheticTokens(cfg.vocab_size, args.seq, args.batch, seed=0)
    pf = Prefetcher(iter(ds), depth=2)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)

    start = 0
    if mgr.latest_step() is not None:
        (params, opt), start = mgr.restore((params, opt))
        start += 1
        print(f"resumed at step {start}")

    t0 = time.time()
    losses = []
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(pf).items()}
        params, opt, metrics = jit_step(params, opt, batch, jnp.asarray(step, jnp.int32))
        losses.append(float(metrics["loss"]))
        if step % 10 == 0:
            dt = (time.time() - t0) / max(1, step - start + 1)
            print(f"step {step:4d}  loss {losses[-1]:.4f}  {dt*1000:.0f} ms/step", flush=True)
        if step and step % 50 == 0:
            mgr.async_save((params, opt), step)
    mgr.wait()
    mgr.save((params, opt), args.steps - 1)
    pf.close()
    print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({(time.time()-t0)/60:.1f} min)")


if __name__ == "__main__":
    main()
