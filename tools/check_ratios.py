"""CI regression gate for the paper's chi/omega competitive ratios.

Runs the SmartPool Table-I benchmark on a tiny trace (vgg11 @ batch 4 —
seconds, not minutes) and compares the SmartPool and CnMem competitive
ratios against tools/ci_baseline.json.  Any regression beyond 1% relative
fails the build; improvements are reported and tolerated.

    PYTHONPATH=src python -m tools.check_ratios            # check
    PYTHONPATH=src python -m tools.check_ratios --write    # refresh baseline
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

BASELINE = Path(__file__).resolve().parent / "ci_baseline.json"
TOLERANCE = 0.01  # 1% relative regression budget
MODELS = ("vgg11",)
BATCH = 4


def measure() -> dict:
    from benchmarks.bench_smartpool import run

    out = {}
    for name, _us, derived in run(batch=BATCH, models=MODELS):
        fields = dict(kv.split("=", 1) for kv in derived.split("|"))
        out[name] = {
            "smartpool_ratio": float(fields["smartpool_ratio"]),
            "cnmem_ratio": float(fields["cnmem_ratio"]),
        }
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--write", action="store_true", help="refresh the baseline file")
    args = ap.parse_args(argv)

    current = measure()
    if args.write:
        BASELINE.write_text(json.dumps(current, indent=2, sort_keys=True) + "\n")
        print(f"wrote {BASELINE}")
        return 0

    baseline = json.loads(BASELINE.read_text())
    failures = []
    for name, ratios in baseline.items():
        if name not in current:
            failures.append(f"{name}: missing from current run")
            continue
        for metric, base in ratios.items():
            now = current[name][metric]
            # Ratios are >= 1.0 by construction; larger is worse.
            if now > base * (1 + TOLERANCE):
                failures.append(f"{name}.{metric}: {now:.4f} vs baseline {base:.4f} (>{TOLERANCE:.0%} regression)")
            else:
                delta = (now - base) / base
                print(f"ok {name}.{metric}: {now:.4f} (baseline {base:.4f}, {delta:+.2%})")
    if failures:
        print("\n".join("FAIL " + f for f in failures), file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
