#!/usr/bin/env python
"""Regenerate the committed example traces under ``examples/traces/``.

Two runs, the two shapes the README quickstart points Perfetto at:

  churn.trace.json       bench_engine-style Poisson churn (renegotiation
                         on): tenant rows with queued/stall/op slices,
                         renegotiation flow arrows, HBM counters.
  mesh_data4.trace.json  a contended data=4 mesh: per-device DMA channel
                         rows, host-link lanes, collective blackout track.

Workloads are seeded and the engine is deterministic, so regenerated files
differ only in the wall-clock fields (re-solve milliseconds in the embedded
report) — ``tools/check_trace.py`` excludes those from its invariants and
CI validates the committed files on every run.

Usage:
  PYTHONPATH=src python tools/export_example_traces.py [--out-dir examples/traces]
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.bench_engine import (
    HW,
    SIZE_THRESHOLD,
    build_templates,
    churn_tenants,
    ledger_sums,
    mesh_tenants,
)
from repro.obs import ObsRecorder, write_trace
from repro.runtime import engine as fast_engine
from repro.runtime.workload import poisson_workload


def export_churn(out_path: str, templates, plans, floors) -> None:
    items = poisson_workload(
        list(templates), 40, 20_000.0, seed=7, iterations=(2, 3)
    )
    mean_floor = sum(floors.values()) / len(floors)
    recorder = ObsRecorder()
    rt = fast_engine.MemoryRuntime(
        HW, budget=int(mean_floor * 10), channels=2, renegotiate=True,
        replan_size_threshold=SIZE_THRESHOLD, obs=recorder,
    )
    report = rt.run(churn_tenants(fast_engine, templates, plans, items))
    assert ledger_sums(report), "churn example: ledger does not sum"
    trace = write_trace(out_path, recorder, report)
    print(f"wrote {out_path}: {len(trace['traceEvents'])} events, "
          f"{report.renegotiations} renegotiations")


def export_mesh(out_path: str, templates, plans) -> None:
    recorder = ObsRecorder()
    rt = fast_engine.MemoryRuntime(
        HW, channels=2, link=fast_engine.HostLink.make(HW.link_bw, 2),
        obs=recorder,
    )
    report = rt.run(mesh_tenants(fast_engine, templates, plans, 4, 3))
    assert ledger_sums(report), "mesh example: ledger does not sum"
    trace = write_trace(out_path, recorder, report)
    print(f"wrote {out_path}: {len(trace['traceEvents'])} events, "
          f"{len(recorder.blackouts)} link blackouts")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "examples", "traces"))
    args = ap.parse_args(argv)

    os.makedirs(args.out_dir, exist_ok=True)
    templates, plans, floors = build_templates()
    export_churn(os.path.join(args.out_dir, "churn.trace.json"),
                 templates, plans, floors)
    export_mesh(os.path.join(args.out_dir, "mesh_data4.trace.json"),
                templates, plans)
    return 0


if __name__ == "__main__":
    sys.exit(main())
