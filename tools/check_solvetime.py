"""CI regression gate for trace->plan solve time (Issue 3).

Runs the solve-time benchmark in smoke mode (seconds) and compares each
stage against tools/solvetime_baseline.json, failing the build on a >1.25x
solve-time regression (mirroring the chi/omega ratio gate in
tools/check_ratios.py); plan-equality failures fail outright.

The gated quantity is the *fast/reference time ratio* measured in the same
process, not absolute wall time: the frozen reference solver
(core/_solver_reference.py) doubles as a per-machine speed normalizer, so a
slower CI runner shifts both numerator and denominator and the committed
baseline stays valid across machines.  Absolute times are recorded in the
baseline for context.  Wall time is still noisy at smoke scale, so a
failing measurement is retried once (minima taken) and stages that complete
under a 10 ms floor never fail.

    PYTHONPATH=src python -m tools.check_solvetime            # check
    PYTHONPATH=src python -m tools.check_solvetime --write    # refresh baseline
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

BASELINE = Path(__file__).resolve().parent / "solvetime_baseline.json"
TOLERANCE = 0.25   # fail on >1.25x relative solve-time regression
NOISE_FLOOR_S = 0.010  # stages still under 10 ms are noise, never a failure


def measure(repeats: int = 1) -> dict:
    """Per-stage {fast_s, ref_s} minima over ``repeats`` smoke runs."""
    from benchmarks.bench_solvetime import run

    out: dict = {"plans_equal": True, "stages": {}}
    for _ in range(repeats):
        result = run(smoke=True)
        out["plans_equal"] &= result["all_plans_equal"]
        for r in result["traces"]:
            name = r["name"]
            for stage, cell in (
                ("smartpool.best_fit", r["smartpool"]["best_fit"]),
                ("smartpool.first_fit", r["smartpool"]["first_fit"]),
                ("autoswap", r["autoswap"]),
                ("pipeline", r["pipeline"]),
            ):
                k = f"{name}/{stage}"
                prev = out["stages"].get(k)
                cur = {"fast_s": cell["fast_s"], "ref_s": cell["ref_s"]}
                if prev is not None:
                    cur = {m: min(prev[m], cur[m]) for m in cur}
                out["stages"][k] = cur
    return out


def _ratio(cell: dict) -> float:
    return cell["fast_s"] / cell["ref_s"] if cell["ref_s"] else float("inf")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--write", action="store_true", help="refresh the baseline file")
    args = ap.parse_args(argv)

    current = measure(repeats=2 if args.write else 1)
    if not current["plans_equal"]:
        print("FAIL plans_equal: fast solvers diverged from the frozen reference", file=sys.stderr)
        return 1
    if args.write:
        BASELINE.write_text(json.dumps(current, indent=2, sort_keys=True) + "\n")
        print(f"wrote {BASELINE}")
        return 0

    baseline = json.loads(BASELINE.read_text())
    stages = dict(current["stages"])
    retried = False
    failures = []

    def regressed(now: dict, base: dict) -> bool:
        return (
            _ratio(now) > _ratio(base) * (1 + TOLERANCE)
            and now["fast_s"] > NOISE_FLOOR_S
        )

    # A stage measured now but absent from the baseline would silently ship
    # without regression coverage — force a baseline refresh instead.
    for name in sorted(set(stages) - set(baseline["stages"])):
        failures.append(f"{name}: not in baseline — refresh with --write")

    for name, base in sorted(baseline["stages"].items()):
        now = stages.get(name)
        if now is None:
            failures.append(f"{name}: missing from current run")
            continue
        if regressed(now, base) and not retried:
            # One retry for the whole run: wall time is noisy, take minima.
            retried = True
            again = measure()["stages"]
            stages = {
                k: {m: min(v[m], again.get(k, v)[m]) for m in v}
                for k, v in stages.items()
            }
            now = stages[name]
        msg = (
            f"{name}: fast/ref {_ratio(now):.3f} vs baseline {_ratio(base):.3f} "
            f"(fast {now['fast_s']*1e3:.1f}ms, baseline {base['fast_s']*1e3:.1f}ms)"
        )
        if regressed(now, base):
            failures.append(f"{msg} — >{TOLERANCE:.0%} solve-time regression")
        else:
            print(f"ok {msg}")
    if failures:
        print("\n".join("FAIL " + f for f in failures), file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
