"""CI gate for the repro.tune decision surfaces (Issue 8).

Runs ``benchmarks.bench_tune`` in smoke mode in-process and fails the build
unless the tuned decisions hold their ground against the static defaults:

  * **victim** — the ledger policy's mean newcomer queue wait is
    equal-or-lower than floor-greedy's at equal-or-lower total added victim
    overhead, with zero overflow events (the probing must never buy latency
    with budget violations);
  * **budget_split** — the coordinate-descent split is never worse than
    ``proportional_shares`` on any cell (strict wins are asserted by the
    committed full-run ``BENCH_tune.json``, not re-gated at smoke scale);
  * **defaults** — with every tuning knob at its default the victim
    workload's report is bit-identical to the frozen
    ``runtime/_engine_reference.py`` engine.

The simulator is deterministic, so these are exact comparisons — no
tolerance, no retry (unlike the wall-time gates in check_enginetime).

    PYTHONPATH=src python -m tools.check_tune
"""

from __future__ import annotations

import sys


def main(argv=None) -> int:
    from benchmarks.bench_tune import (
        budget_split_cells,
        build_victim_workload,
        defaults_identity,
        victim_cell,
    )

    failures = []
    workload = build_victim_workload(smoke=True, seed=42)

    victim = victim_cell(workload)
    g, l = victim["greedy"], victim["ledger"]
    if l["newcomer_mean_wait_s"] > g["newcomer_mean_wait_s"]:
        failures.append(
            f"victim: ledger mean wait {l['newcomer_mean_wait_s']*1e3:.2f}ms "
            f"> greedy {g['newcomer_mean_wait_s']*1e3:.2f}ms"
        )
    ledger_added = sum(victim["ledger_added_victim_overhead"].values())
    greedy_added = sum(victim["greedy_added_victim_overhead"].values())
    if ledger_added > greedy_added + 1e-12:
        failures.append(
            f"victim: ledger added overhead {ledger_added*100:.2f}pp "
            f"> greedy {greedy_added*100:.2f}pp"
        )
    if l["overflow_events"] != 0:
        failures.append(f"victim: {l['overflow_events']} overflow events under ledger")
    print(
        f"ok victim: ledger {l['newcomer_mean_wait_s']*1e3:.2f}ms vs greedy "
        f"{g['newcomer_mean_wait_s']*1e3:.2f}ms mean wait "
        f"(added overhead {ledger_added*100:.2f}pp vs {greedy_added*100:.2f}pp, "
        f"{victim['ledger_probes']} probes)"
    )

    split = budget_split_cells(smoke=True)
    for name, cell in split["cells"].items():
        if not cell["not_worse"]:
            failures.append(
                f"split[{name}]: tuned stall {cell['tuned_stall_s']*1e3:.3f}ms "
                f"> proportional {cell['proportional_stall_s']*1e3:.3f}ms"
            )
        if not cell["all_completed"]:
            failures.append(f"split[{name}]: tuned run left tenants incomplete")
        print(
            f"ok split[{name}]: proportional {cell['proportional_stall_s']*1e3:.3f}ms "
            f"-> tuned {cell['tuned_stall_s']*1e3:.3f}ms"
        )

    identity = defaults_identity(workload)
    if not identity["bit_for_bit_equal"]:
        failures.append("defaults: report diverged from runtime/_engine_reference.py")
    else:
        print("ok defaults: bit-identical to the frozen reference engine")

    if failures:
        print("\n".join("FAIL " + f for f in failures), file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
