"""CI regression gate for runtime-engine throughput (Issue 6).

Runs the engine benchmark in smoke mode (seconds) and compares each cell
against tools/enginetime_baseline.json, failing the build on a >1.25x
regression — mirroring tools/check_solvetime.py, which gates the solvers
the same way.  Report-equality or suffix-replay failures fail outright.

The gated quantity is the *fast/reference time ratio* measured in the same
process, not absolute wall time: the frozen reference engine
(runtime/_engine_reference.py) doubles as a per-machine speed normalizer,
so a slower CI runner shifts both numerator and denominator and the
committed baseline stays valid across machines.  Absolute times are
recorded in the baseline for context.  Wall time is still noisy at smoke
scale, so a failing measurement is retried once (minima taken) and cells
that complete under a 10 ms floor never fail.

    PYTHONPATH=src python -m tools.check_enginetime            # check
    PYTHONPATH=src python -m tools.check_enginetime --write    # refresh baseline
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

BASELINE = Path(__file__).resolve().parent / "enginetime_baseline.json"
TOLERANCE = 0.25   # fail on >1.25x relative engine-time regression
NOISE_FLOOR_S = 0.010  # cells still under 10 ms are noise, never a failure
CELLS = ("churn", "churn_reneg", "churn_obs", "mesh_data4", "tune")


def measure(repeats: int = 1) -> dict:
    """Per-cell {fast_s, ref_s} minima over ``repeats`` smoke runs."""
    from benchmarks.bench_engine import run

    out: dict = {"reports_equal": True, "suffix_replay_identical": True,
                 "ledger_sums": True, "cells": {}}
    for _ in range(repeats):
        result = run(smoke=True)
        out["reports_equal"] &= result["all_reports_equal"]
        out["suffix_replay_identical"] &= result["suffix_replay_identical"]
        out["ledger_sums"] &= result.get("ledger_sums", True)
        for name in CELLS:
            cell = result[name]
            cur = {"fast_s": cell["fast_s"], "ref_s": cell["ref_s"]}
            prev = out["cells"].get(name)
            if prev is not None:
                cur = {m: min(prev[m], cur[m]) for m in cur}
            out["cells"][name] = cur
    return out


def _ratio(cell: dict) -> float:
    return cell["fast_s"] / cell["ref_s"] if cell["ref_s"] else float("inf")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--write", action="store_true", help="refresh the baseline file")
    args = ap.parse_args(argv)

    current = measure(repeats=2 if args.write else 1)
    if not current["reports_equal"]:
        print("FAIL reports_equal: fast engine diverged from the frozen reference", file=sys.stderr)
        return 1
    if not current["suffix_replay_identical"]:
        print("FAIL suffix_replay: snapshot resume diverged from full replay", file=sys.stderr)
        return 1
    if not current["ledger_sums"]:
        print("FAIL ledger_sums: attribution buckets do not sum to overhead", file=sys.stderr)
        return 1
    if args.write:
        BASELINE.write_text(json.dumps(current, indent=2, sort_keys=True) + "\n")
        print(f"wrote {BASELINE}")
        return 0

    baseline = json.loads(BASELINE.read_text())
    cells = dict(current["cells"])
    retried = False
    failures = []

    def regressed(now: dict, base: dict) -> bool:
        return (
            _ratio(now) > _ratio(base) * (1 + TOLERANCE)
            and now["fast_s"] > NOISE_FLOOR_S
        )

    # A cell measured now but absent from the baseline would silently ship
    # without regression coverage — force a baseline refresh instead.
    for name in sorted(set(cells) - set(baseline["cells"])):
        failures.append(f"{name}: not in baseline — refresh with --write")

    for name, base in sorted(baseline["cells"].items()):
        now = cells.get(name)
        if now is None:
            failures.append(f"{name}: missing from current run")
            continue
        if regressed(now, base) and not retried:
            # One retry for the whole run: wall time is noisy, take minima.
            retried = True
            again = measure()["cells"]
            cells = {
                k: {m: min(v[m], again.get(k, v)[m]) for m in v}
                for k, v in cells.items()
            }
            now = cells[name]
        msg = (
            f"{name}: fast/ref {_ratio(now):.3f} vs baseline {_ratio(base):.3f} "
            f"(fast {now['fast_s']*1e3:.1f}ms, baseline {base['fast_s']*1e3:.1f}ms)"
        )
        if regressed(now, base):
            failures.append(f"{msg} — >{TOLERANCE:.0%} engine-time regression")
        else:
            print(f"ok {msg}")
    if failures:
        print("\n".join("FAIL " + f for f in failures), file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
