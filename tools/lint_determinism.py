#!/usr/bin/env python
"""Determinism lint: AST pass over the bit-for-bit reference-pinned modules.

The fast solver/engine implementations are pinned byte-identical to frozen
references (``core/_solver_reference.py``, ``runtime/_engine_reference.py``,
``tests/test_*_equiv*``).  The bug classes that silently break such pins are
exactly the order-nondeterminism ones — results that depend on ``set``
iteration order, which varies with PYTHONHASHSEED for str/bytes-keyed sets:

  iter-unordered       iterating a set in a ``for`` loop or comprehension
                       (remedy: ``sorted(...)`` the set first)
  minmax-tie-unordered ``min``/``max`` with a ``key=`` over a set — equal
                       keys tie-break by iteration order (remedy: sort, or
                       fold the tiebreak into the key)
  float-sum-unordered  ``sum``/``math.fsum`` over a set — float addition is
                       not associative, so accumulation order changes the
                       result bit pattern
  set-pop              ``set.pop()`` returns an arbitrary element

Membership tests, ``len``, ``add``/``discard`` and set algebra are fine and
not flagged; ``sorted(<set>)`` is the approved laundering point.

Set-typedness is inferred per scope: set literals, set comprehensions,
``set()``/``frozenset()`` calls, set algebra over those, annotations, and
names assigned any of the above (a name ever rebound to a non-set value in
the same scope drops out — the lint prefers silence to false positives).

Stdlib-only on purpose: this gate runs where the jax backend (and the repo
package itself) cannot import.

Usage:
  python tools/lint_determinism.py [FILE ...]   # default: the pinned modules
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# The reference-pinned modules every PR must keep deterministic.
DEFAULT_FILES = [
    "src/repro/runtime/engine.py",
    "src/repro/core/smartpool.py",
    "src/repro/core/autoswap.py",
    "src/repro/tune/victim.py",
    # Streaming-monitor modules: sketch compaction/merge order and alert
    # emission land in the recorder stream that repro.analyze.schedule_check
    # consumes, so they must be exactly as deterministic as the engine.
    "src/repro/obs/sketch.py",
    "src/repro/obs/windows.py",
    "src/repro/obs/monitor.py",
]

SET_BUILTINS = {"set", "frozenset"}
SET_METHODS = {"union", "intersection", "difference", "symmetric_difference", "copy"}
SET_ANNOTATIONS = {"set", "frozenset", "Set", "FrozenSet", "MutableSet", "AbstractSet"}


def _annotation_is_set(node) -> bool:
    """``x: set``, ``x: set[int]``, ``x: typing.Set[int]`` …"""
    if isinstance(node, ast.Subscript):
        node = node.value
    if isinstance(node, ast.Attribute):
        return node.attr in SET_ANNOTATIONS
    return isinstance(node, ast.Name) and node.id in SET_ANNOTATIONS


class Scope:
    """Set-typed name inference for one function (or module) body."""

    def __init__(self, body):
        self.set_names: set[str] = set()
        dropped: set[str] = set()
        for stmt in ast.walk(ast.Module(body=list(body), type_ignores=[])):
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
                continue  # nested scopes are linted separately
            targets: list = []
            value = None
            if isinstance(stmt, ast.Assign):
                targets, value = stmt.targets, stmt.value
            elif isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target, ast.Name):
                if _annotation_is_set(stmt.annotation):
                    self.set_names.add(stmt.target.id)
                    continue
                targets, value = [stmt.target], stmt.value
            elif isinstance(stmt, ast.AugAssign):
                continue  # |=/&= keeps the existing inference
            for t in targets:
                if not isinstance(t, ast.Name):
                    continue
                if value is not None and self.is_set_expr(value):
                    self.set_names.add(t.id)
                else:
                    dropped.add(t.id)
        # A name both set- and non-set-assigned is ambiguous: stay silent.
        self.set_names -= dropped

    def is_set_expr(self, node) -> bool:
        if isinstance(node, (ast.Set, ast.SetComp)):
            return True
        if isinstance(node, ast.Name):
            return node.id in self.set_names
        if isinstance(node, ast.BinOp) and isinstance(
                node.op, (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)):
            return self.is_set_expr(node.left) or self.is_set_expr(node.right)
        if isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Name) and f.id in SET_BUILTINS:
                return True
            if isinstance(f, ast.Attribute) and f.attr in SET_METHODS:
                return self.is_set_expr(f.value)
        return False


class Linter(ast.NodeVisitor):
    def __init__(self, path: str, tree: ast.AST):
        self.path = path
        self.findings: list[tuple[int, str, str]] = []
        self._walk_scope(tree.body)

    def flag(self, node, rule: str, msg: str) -> None:
        self.findings.append((node.lineno, rule, msg))

    def _walk_scope(self, body) -> None:
        self.scope = Scope(body)
        for stmt in body:
            self._visit_stmts(stmt)

    def _visit_stmts(self, node) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            outer = self.scope
            self._walk_scope(node.body)
            self.scope = outer
            return
        if isinstance(node, ast.ClassDef):
            outer = self.scope
            self._walk_scope(node.body)
            self.scope = outer
            return
        self._check(node)
        for child in ast.iter_child_nodes(node):
            self._visit_stmts(child)

    def _check(self, node) -> None:
        scope = self.scope
        if isinstance(node, (ast.For, ast.AsyncFor)) and scope.is_set_expr(node.iter):
            self.flag(node, "iter-unordered",
                      "for-loop over a set: iteration order is "
                      "hash-dependent; iterate sorted(...) instead")
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp, ast.GeneratorExp)):
            for gen in node.generators:
                if scope.is_set_expr(gen.iter):
                    self.flag(node, "iter-unordered",
                              "comprehension over a set: iteration order is "
                              "hash-dependent; iterate sorted(...) instead")
        if isinstance(node, ast.Call):
            f = node.func
            fname = f.id if isinstance(f, ast.Name) else (
                f.attr if isinstance(f, ast.Attribute) else None)
            first_is_set = bool(node.args) and scope.is_set_expr(node.args[0])
            if fname in ("min", "max") and first_is_set and any(
                    kw.arg == "key" for kw in node.keywords):
                self.flag(node, "minmax-tie-unordered",
                          f"{fname}(key=...) over a set: equal keys "
                          "tie-break by hash-dependent iteration order")
            if fname in ("sum", "fsum") and first_is_set:
                self.flag(node, "float-sum-unordered",
                          f"{fname}() over a set: float accumulation order "
                          "is hash-dependent")
            if (isinstance(f, ast.Attribute) and f.attr == "pop"
                    and not node.args and scope.is_set_expr(f.value)):
                self.flag(node, "set-pop",
                          "set.pop() returns an arbitrary element")


def lint_file(path: Path) -> list[str]:
    try:
        tree = ast.parse(path.read_text(), filename=str(path))
    except (OSError, SyntaxError) as e:
        return [f"{path}: unparseable: {e}"]
    linter = Linter(str(path), tree)
    return [
        f"{path}:{line}: [{rule}] {msg}"
        for line, rule, msg in sorted(linter.findings)
    ]


def main(argv=None) -> int:
    args = (argv if argv is not None else sys.argv[1:])
    paths = [Path(a) for a in args] or [REPO / f for f in DEFAULT_FILES]
    findings: list[str] = []
    for p in paths:
        findings.extend(lint_file(p))
    for f in findings:
        print(f"FAIL {f}")
    if not findings:
        print(f"ok   determinism lint: {len(paths)} file(s) clean")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
