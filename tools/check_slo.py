"""CI gate for the streaming SLO monitor (Issue 10).

Runs the ``benchmarks.bench_churn`` SLO-percentile cell in smoke mode
in-process and fails the build unless the streaming telemetry holds:

  * **purity** — the simulated report with the monitor armed is
    bit-identical to the unmonitored run (the monitor is a pure observer);
  * **sketch accuracy** — per-priority-class p50/p95/p99 queue waits from
    the streaming quantile sketch match the exact post-hoc percentiles
    within the sketch's self-reported rank-error bound;
  * **alert track** — the generous guard SLO emits zero alerts (no false
    alarms), the deliberately tight SLO does fire (the detector works),
    and the alert stream is ts-sorted.

The engine and the monitor are deterministic, so these are exact
comparisons — no tolerance, no retry.

    PYTHONPATH=src python -m tools.check_slo
"""

from __future__ import annotations

import sys


def main(argv=None) -> int:
    from benchmarks.bench_churn import slo_cell

    cell = slo_cell(smoke=True, seed=42)
    acc = cell["acceptance"]
    failures = []
    if not acc["monitor_pure"]:
        failures.append("monitor armed changed the simulated report")
    if not acc["sketch_within_bounds"]:
        bad = [
            f"{cls}.{q}"
            for cls, e in sorted(cell["classes"].items())
            for q in ("p50", "p95", "p99")
            if not e[q]["within_bound"]
        ]
        failures.append(f"sketch quantiles outside rank-error bound: {bad}")
    if not acc["zero_false_alarms"]:
        failures.append(
            f"guard SLO raised {cell['alerts']['guard']} false alarm(s)")
    if not acc["tight_slo_fires"]:
        failures.append("tight SLO never fired on an overloaded storm")
    if not acc["alerts_ts_sorted"]:
        failures.append("alert stream is not ts-sorted")

    for cls in sorted(cell["classes"]):
        e = cell["classes"][cls]
        print(
            f"ok {cls}: n={e['count']} bound±{e['rank_error_bound']} ranks  "
            + "  ".join(
                f"{q}={e[q]['sketch']*1e3:.3f}/{e[q]['exact']*1e3:.3f}ms"
                for q in ("p50", "p95", "p99")
            )
        )
    print(
        f"ok alerts: guard={cell['alerts']['guard']} "
        f"tight={cell['alerts']['tight']} ts_sorted={cell['alerts']['ts_sorted']}; "
        f"monitor pure: {acc['monitor_pure']}"
    )

    if failures:
        print("\n".join("FAIL " + f for f in failures), file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
