#!/usr/bin/env python
"""Aggregate committed ``BENCH_*.json`` reports into one trajectory summary.

Every benchmark writes a machine-readable report through
``benchmarks.common.write_bench_json`` (stamped with ``_meta``: schema
version, git SHA, timestamp), and those reports are committed — so the git
history of each ``BENCH_*.json`` *is* the performance trajectory of the
repo.  This tool walks that history (``git log`` + ``git show``), flattens
each revision's numeric scalars into dotted paths, and emits one summary:

  per file, per commit (oldest -> newest): {sha, date, metrics{...}}

plus a human-readable first->last delta table for every metric that moved.
No third-party deps and no jax import — safe anywhere git is.

``--diff REV_A REV_B`` switches to differential mode: every selected file
is loaded at both revisions (``-`` means the working tree) and diffed with
``repro.obs.diffing`` — per-cause ledger delta, quantile shift, and a
ranked top-K regression attribution table, e.g.::

  python tools/bench_history.py BENCH_engine.json --diff HEAD~2 -

Usage:
  python tools/bench_history.py [FILES...] [--json OUT] [--depth N] [--match SUBSTR]
                                [--diff REV_A REV_B] [--top N]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _git(*args: str) -> str:
    out = subprocess.run(
        ["git", *args], capture_output=True, text=True, cwd=REPO, timeout=60
    )
    if out.returncode != 0:
        raise RuntimeError(f"git {' '.join(args)}: {out.stderr.strip()}")
    return out.stdout


def flatten(obj, prefix: str = "", depth: int = 3):
    """Yield (dotted-path, value) for numeric/bool scalars up to ``depth``."""
    if isinstance(obj, bool) or isinstance(obj, (int, float)):
        yield prefix, obj
        return
    if depth <= 0 or not isinstance(obj, dict):
        return
    for k, v in obj.items():
        if k == "_meta":
            continue
        path = f"{prefix}.{k}" if prefix else str(k)
        yield from flatten(v, path, depth - 1)


def history(relpath: str, depth: int) -> list[dict]:
    """Oldest->newest [{sha, date, schema_version, metrics}] for one file."""
    log = _git("log", "--reverse", "--format=%H %cI", "--", relpath)
    entries = []
    for line in log.splitlines():
        sha, _, date = line.strip().partition(" ")
        try:
            payload = json.loads(_git("show", f"{sha}:{relpath}"))
        except (RuntimeError, ValueError):
            continue  # deleted or unparsable at this revision
        meta = payload.get("_meta", {}) if isinstance(payload, dict) else {}
        if meta.get("schema_version") is None:
            print(
                f"warning: {relpath}@{sha[:12]} has no _meta stamp "
                "(written before schema v1); treating its metrics as "
                "schema-less — regenerate or re-stamp the file",
                file=sys.stderr,
            )
        entries.append(
            {
                "sha": sha,
                "date": date,
                "schema_version": meta.get("schema_version"),
                "metrics": dict(flatten(payload, depth=depth)),
            }
        )
    return entries


def delta_table(entries: list[dict], match: str | None) -> list[tuple]:
    """(metric, first, last, n_revisions) for metrics present in >1 revision."""
    if not entries:
        return []
    rows = []
    seen: dict[str, list] = {}
    for e in entries:
        for k, v in e["metrics"].items():
            seen.setdefault(k, []).append(v)
    for k in sorted(seen):
        if match and match not in k:
            continue
        vals = seen[k]
        rows.append((k, vals[0], vals[-1], len(vals)))
    return rows


def run_diff(files: list, rev_a: str, rev_b: str, top: int,
             match: "str | None") -> int:
    """Differential mode: repro.obs.diffing over two revisions per file."""
    try:
        from repro.obs.diffing import diff_runs, format_diff, load_run
    except ImportError:  # invoked without PYTHONPATH=src
        sys.path.insert(0, os.path.join(REPO, "src"))
        from repro.obs.diffing import diff_runs, format_diff, load_run

    status = 0
    for rel in files:
        spec_a = os.path.join(REPO, rel) if rev_a == "-" else f"{rel}@{rev_a}"
        spec_b = os.path.join(REPO, rel) if rev_b == "-" else f"{rel}@{rev_b}"
        try:
            view_a = load_run(spec_a, repo=REPO)
            view_b = load_run(spec_b, repo=REPO)
        except (OSError, ValueError) as e:
            print(f"skip {rel}: {e}", file=sys.stderr)
            status = 1
            continue
        if match:
            view_a.scalars = {k: v for k, v in view_a.scalars.items() if match in k}
            view_b.scalars = {k: v for k, v in view_b.scalars.items() if match in k}
        print(format_diff(diff_runs(view_a, view_b, top_k=top)))
        print()
    return status


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*",
                    help="bench reports (default: all committed BENCH_*.json)")
    ap.add_argument("--json", default=None, help="write the full trajectory here")
    ap.add_argument("--depth", type=int, default=3,
                    help="flattening depth for nested metrics")
    ap.add_argument("--match", default=None,
                    help="only print metrics whose path contains this substring")
    ap.add_argument("--diff", nargs=2, default=None, metavar=("REV_A", "REV_B"),
                    help="diff each file between two git revisions "
                         "('-' = working tree) via repro.obs.diffing")
    ap.add_argument("--top", type=int, default=12,
                    help="rows in the --diff regression table")
    args = ap.parse_args(argv)

    files = args.files or sorted(
        os.path.relpath(p, REPO) for p in glob.glob(os.path.join(REPO, "BENCH_*.json"))
    )
    if not files:
        print("no BENCH_*.json found", file=sys.stderr)
        return 1

    if args.diff:
        return run_diff(files, args.diff[0], args.diff[1], args.top, args.match)

    summary = {}
    for rel in files:
        entries = history(rel, args.depth)
        summary[rel] = entries
        print(f"{rel}: {len(entries)} committed revision(s)")
        for metric, first, last, n in delta_table(entries, args.match):
            if n < 2 or first == last:
                continue
            arrow = f"{first!r} -> {last!r}"
            print(f"  {metric:55s} {arrow}  ({n} revs)")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
