#!/usr/bin/env bash
# CI entry point: tier-1 tests + a smoke benchmark with a competitive-ratio
# regression gate (fails on >1% chi/omega regression vs tools/ci_baseline.json).
# All stages run even when an earlier one fails, so a red tier-1 can't mask
# a ratio regression (or vice versa); the exit code aggregates the stages.
set -uo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
status=0

echo "== hygiene: no tracked bytecode =="
# Stale .pyc files must never land in the tree; the patterns are ignored,
# so anything tracked means a force-add slipped through.
tracked_pyc=$(git ls-files | grep -E '(\.pyc$|__pycache__/)' || true)
if [ -n "$tracked_pyc" ]; then
  echo "FAIL tracked bytecode:"; echo "$tracked_pyc"; status=1
fi

echo "== tier-1 tests =="
python -m pytest -x -q || { echo "FAIL tier-1"; status=1; }

echo "== smoke benchmark: SmartPool on a tiny trace =="
python -m benchmarks.bench_smartpool --models vgg11 --batch 4 || { echo "FAIL smoke bench"; status=1; }

echo "== chi/omega competitive-ratio regression gate =="
python -m tools.check_ratios || { echo "FAIL ratio gate"; status=1; }

echo "== solve-time smoke benchmark + regression gate =="
# Runs benchmarks.bench_solvetime in smoke mode (fast-vs-reference plan
# equality on every cell) and fails on >1.25x regression of the
# fast/reference solve-time ratio vs tools/solvetime_baseline.json.
python -m tools.check_solvetime || { echo "FAIL solvetime gate"; status=1; }

echo "== engine smoke benchmark + throughput regression gate =="
# Runs benchmarks.bench_engine in smoke mode (fast engine bit-for-bit equal
# to runtime/_engine_reference.py on every cell, suffix replay byte-identical)
# and fails on >1.25x regression of the fast/reference engine-time ratio vs
# tools/enginetime_baseline.json.  Committed BENCH_engine.json is the full run.
python -m tools.check_enginetime || { echo "FAIL enginetime gate"; status=1; }

echo "== runtime smoke benchmark: DMA channel scaling + colocation gates =="
# Exits non-zero unless K=2 channels strictly beat K=1 somewhere (never losing)
# and colocation lands under the sum of isolated peaks.  Committed
# BENCH_runtime.json is the full-mode run; the smoke output stays out of tree.
python -m benchmarks.bench_runtime --smoke --out "${TMPDIR:-/tmp}/BENCH_runtime_smoke.json" \
  || { echo "FAIL runtime bench"; status=1; }

echo "== churn smoke benchmark: renegotiation vs FIFO queueing =="
# Exits non-zero unless renegotiation strictly reduces the newcomers' mean
# queue wait under the same Poisson workload with bounded victim overhead,
# zero overflow events, and the 1-tenant/K=2 path bit-for-bit equal to the
# frozen reference simulator.  Committed BENCH_churn.json is the full run.
python -m benchmarks.bench_churn --smoke --out "${TMPDIR:-/tmp}/BENCH_churn_smoke.json" \
  || { echo "FAIL churn bench"; status=1; }

echo "== slo smoke gate: streaming sketch accuracy + clean alert track =="
# Re-runs the bench_churn SLO cell in smoke mode and fails unless the
# monitored report is bit-identical to the unmonitored one, per-class
# p50/p95/p99 queue waits from the streaming sketch match exact post-hoc
# percentiles within the sketch's rank-error bound, the guard SLO raises
# zero false alarms, and the tight SLO does fire.
python -m tools.check_slo || { echo "FAIL slo gate"; status=1; }

echo "== tune smoke gate: ledger victim policy + SLO-equalized splits =="
# Re-runs the bench_tune smoke cells in-process and fails unless the ledger
# victim policy's mean newcomer wait is equal-or-lower than floor-greedy's
# at equal-or-lower added victim overhead (zero overflow), tuned budget
# splits are never worse than proportional, and the all-defaults report
# stays bit-identical to runtime/_engine_reference.py.  Committed
# BENCH_tune.json is the full run.
python -m tools.check_tune || { echo "FAIL tune gate"; status=1; }

echo "== obs trace export smoke + trace validation =="
# Regenerates both example traces into a temp dir, then validates the fresh
# and the committed copies with tools/check_trace.py: well-formed Chrome
# trace events, non-overlapping slices per track, paired flow arrows, and a
# stall-attribution ledger that sums exactly to each tenant's overhead.
python tools/export_example_traces.py --out-dir "${TMPDIR:-/tmp}/repro_traces" \
  && python tools/check_trace.py --invariants "${TMPDIR:-/tmp}/repro_traces"/*.trace.json \
  && python tools/check_trace.py --invariants examples/traces/*.trace.json \
  || { echo "FAIL trace export"; status=1; }

echo "== static analysis: determinism lint + certified --verify smokes =="
# lint_determinism is stdlib-only (no repro import, no jax) so it gates even
# where the backend is unavailable; the --verify smokes run the colocate and
# shardplan launchers with the static plan verifier + event-log race
# detector armed (repro.analyze), failing on any invariant violation.
python tools/lint_determinism.py || { echo "FAIL determinism lint"; status=1; }
python -m repro.launch.analyze -q examples/traces/*.trace.json \
  || { echo "FAIL trace certification"; status=1; }
python -m repro.launch.colocate --arch qwen3-4b --smoke --tenants prefill,decode \
    --renegotiate --iterations 2 --verify >/dev/null \
  || { echo "FAIL colocate --verify"; status=1; }
python -m repro.launch.shardplan --arch qwen3-4b --smoke --mesh data=4 --verify >/dev/null \
  || { echo "FAIL shardplan --verify"; status=1; }

echo "== dist smoke benchmark: per-shard plans + host-link contention gates =="
# Exits non-zero unless the per-device planned peak stays within the shard
# fraction of the replicated plan (+ replicated bytes), the shared-link
# contention model moves at least one swap transfer vs the contention-free
# baseline, the collective-aware schedule is never worse than the
# contention-blind one, and 1x1-mesh plans stay byte-identical to the
# single-device pipeline.  Committed BENCH_dist.json is the full-mode run.
python -m benchmarks.bench_dist --smoke --out "${TMPDIR:-/tmp}/BENCH_dist_smoke.json" \
  || { echo "FAIL dist bench"; status=1; }

[ "$status" -eq 0 ] && echo "CI OK" || echo "CI FAILED"
exit "$status"
