#!/usr/bin/env bash
# CI entry point: tier-1 tests + a smoke benchmark with a competitive-ratio
# regression gate (fails on >1% chi/omega regression vs tools/ci_baseline.json).
# All stages run even when an earlier one fails, so a red tier-1 can't mask
# a ratio regression (or vice versa); the exit code aggregates the stages.
set -uo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
status=0

echo "== tier-1 tests =="
python -m pytest -x -q || { echo "FAIL tier-1"; status=1; }

echo "== smoke benchmark: SmartPool on a tiny trace =="
python -m benchmarks.bench_smartpool --models vgg11 --batch 4 || { echo "FAIL smoke bench"; status=1; }

echo "== chi/omega competitive-ratio regression gate =="
python -m tools.check_ratios || { echo "FAIL ratio gate"; status=1; }

[ "$status" -eq 0 ] && echo "CI OK" || echo "CI FAILED"
exit "$status"
