#!/usr/bin/env python
"""Validate a repro.obs trace file (CI gate for trace export).

Checks, per file:
  1. Well-formed Chrome-trace-event JSON: an object with a ``traceEvents``
     list, every event carrying a known phase, numeric ts/dur, and pid/tid
     where the phase requires them; ``otherData.schema_version`` matches.
  2. Slices are non-overlapping per track: within each (pid, tid) row the
     ``X`` slices, sorted by start, never start before the previous slice
     ended (modulo float-ulp tolerance from the seconds->µs scaling).
  3. Flow events pair up: every flow-start (``ph: s``) id terminates in
     exactly one flow-finish (``ph: f``) and no finish lacks a start.
  4. The embedded report's stall-attribution ledgers sum to the reported
     overhead: for every completed tenant, the cause buckets (everything
     except the informational keys) add up to ``overhead_s``.
  5. The alerts track (pid 5, present only for monitored runs) is
     well-formed: every alert is an instant event with numeric value/
     threshold args, the track is ts-sorted, and every alert names an SLO
     registered in ``otherData.slos``.

With ``--invariants``, each trace is additionally swept by the event-log
race detector (``repro.analyze.schedule_check``): channel/lane transfer
exclusivity, blackout exclusion, accountant monotonicity, reservation
isolation and ledger closure — so committed traces are certified, not just
well-formed.

Usage:
  python tools/check_trace.py [--invariants] TRACE [TRACE ...]

Exit 0 when every file passes; prints one line per failure otherwise.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

EXPECT_SCHEMA = 1
KNOWN_PHASES = {"X", "B", "E", "i", "I", "C", "M", "s", "t", "f"}
# Attribution keys outside the sums-to-overhead invariant: the total itself,
# admission queueing (precedes the overhead window) and host wall-clock.
LEDGER_INFORMATIONAL = {"overhead_s", "queue_wait_s", "renegotiation_solve_s"}
PID_ALERTS = 5


def _tol(x: float) -> float:
    return 1e-6 + 1e-9 * abs(x)


def check_trace(path: str) -> list[str]:
    errors: list[str] = []
    try:
        with open(path) as f:
            trace = json.load(f)
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable trace JSON: {e}"]

    if not isinstance(trace, dict) or not isinstance(trace.get("traceEvents"), list):
        return [f"{path}: not a trace-event JSON object with a traceEvents list"]
    events = trace["traceEvents"]
    other = trace.get("otherData", {})
    if other.get("schema_version") != EXPECT_SCHEMA:
        errors.append(
            f"{path}: otherData.schema_version "
            f"{other.get('schema_version')!r} != {EXPECT_SCHEMA}"
        )

    # --- 1. event well-formedness, collecting slices and flows on the way
    slices: dict[tuple, list[tuple[float, float, str]]] = {}
    flow_starts: dict = {}
    flow_finishes: dict = {}
    for k, e in enumerate(events):
        where = f"{path}: traceEvents[{k}]"
        ph = e.get("ph")
        if ph not in KNOWN_PHASES:
            errors.append(f"{where}: unknown phase {ph!r}")
            continue
        if "name" not in e or "pid" not in e:
            errors.append(f"{where}: missing name/pid")
            continue
        if ph == "M":
            continue  # metadata: no timestamp
        ts = e.get("ts")
        if not isinstance(ts, (int, float)):
            errors.append(f"{where}: non-numeric ts {ts!r}")
            continue
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"{where}: X slice with bad dur {dur!r}")
                continue
            key = (e["pid"], e.get("tid", 0))
            slices.setdefault(key, []).append((float(ts), float(dur), e["name"]))
        elif ph == "C":
            args = e.get("args")
            if not isinstance(args, dict) or not all(
                isinstance(v, (int, float)) for v in args.values()
            ):
                errors.append(f"{where}: counter with non-numeric args {args!r}")
        elif ph in ("s", "f"):
            book = flow_starts if ph == "s" else flow_finishes
            fid = e.get("id")
            if fid is None:
                errors.append(f"{where}: flow event without id")
            elif fid in book:
                errors.append(f"{where}: duplicate flow {ph!r} id {fid!r}")
            else:
                book[fid] = where

    # --- 2. per-track slice overlap
    for (pid, tid), rows in sorted(slices.items()):
        rows.sort()
        prev_end, prev_name = None, None
        for ts, dur, name in rows:
            if prev_end is not None and ts < prev_end - _tol(prev_end):
                errors.append(
                    f"{path}: track pid={pid} tid={tid}: slice {name!r} at "
                    f"ts={ts} overlaps previous {prev_name!r} ending {prev_end}"
                )
            end = ts + dur
            if prev_end is None or end > prev_end:
                prev_end, prev_name = end, name

    # --- 3. flow pairing
    for fid, where in sorted(flow_starts.items()):
        if fid not in flow_finishes:
            errors.append(f"{where}: flow start id {fid!r} never finishes")
    for fid, where in sorted(flow_finishes.items()):
        if fid not in flow_starts:
            errors.append(f"{where}: flow finish id {fid!r} without a start")

    # --- 4. alerts track: instant events only, ts-sorted, every alert
    # names a registered SLO (vacuous for traces without a monitor).
    registered = {s.get("name") for s in other.get("slos", [])
                  if isinstance(s, dict)}
    prev_ts = None
    for k, e in enumerate(events):
        if e.get("pid") != PID_ALERTS or e.get("ph") == "M":
            continue
        where = f"{path}: traceEvents[{k}]"
        if e.get("ph") != "i":
            errors.append(f"{where}: alerts track carries non-instant "
                          f"phase {e.get('ph')!r}")
            continue
        args = e.get("args")
        if not isinstance(args, dict) or not isinstance(
                args.get("value"), (int, float)) or not isinstance(
                args.get("threshold"), (int, float)):
            errors.append(f"{where}: alert without numeric value/threshold args")
            continue
        slo = args.get("slo")
        if slo not in registered:
            errors.append(f"{where}: alert names unregistered SLO {slo!r} "
                          f"(registered: {sorted(registered)})")
        ts = e.get("ts")
        if prev_ts is not None and isinstance(ts, (int, float)) and ts < prev_ts:
            errors.append(f"{where}: alerts track not ts-sorted "
                          f"({ts} after {prev_ts})")
        if isinstance(ts, (int, float)):
            prev_ts = ts

    # --- 5. attribution ledgers in the embedded report
    report = other.get("report")
    if isinstance(report, dict):
        checked = 0
        for t in report.get("tenants", ()):
            if t.get("status") != "completed":
                continue
            ledger = t.get("attribution")
            if not isinstance(ledger, dict):
                errors.append(f"{path}: tenant {t.get('name')!r} has no attribution ledger")
                continue
            total = ledger.get("overhead_s", 0.0)
            summed = sum(
                v for kk, v in ledger.items() if kk not in LEDGER_INFORMATIONAL
            )
            if abs(summed - total) > _tol(total):
                errors.append(
                    f"{path}: tenant {t.get('name')!r} ledger sums to "
                    f"{summed!r}, overhead_s is {total!r}"
                )
            checked += 1
        if checked == 0 and report.get("tenants"):
            errors.append(f"{path}: embedded report has no completed tenants to check")
    return errors


def check_invariants(path: str) -> list[str]:
    """Race-detector sweep (repro.analyze) over one trace file."""
    try:
        from repro.analyze import verify_trace_file
    except ImportError:  # direct invocation without PYTHONPATH=src
        sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
        from repro.analyze import verify_trace_file

    cert = verify_trace_file(path)
    return [
        f"{path}: invariant {v['invariant']} [{v['subject']}]: {v['message']}"
        for v in cert.violations()
    ]


def main(argv=None) -> int:
    paths = list(argv if argv is not None else sys.argv[1:])
    invariants = "--invariants" in paths
    if invariants:
        paths.remove("--invariants")
    if not paths:
        print("usage: check_trace.py [--invariants] TRACE [TRACE ...]",
              file=sys.stderr)
        return 2
    failures = 0
    for path in paths:
        errs = check_trace(path)
        if invariants and not errs:
            errs = check_invariants(path)
        if errs:
            failures += 1
            for e in errs:
                print(f"FAIL {e}")
        else:
            with open(path) as f:
                n = len(json.load(f)["traceEvents"])
            certified = ", schedule invariants hold" if invariants else ""
            print(f"ok   {path}: {n} events, tracks and ledgers "
                  f"consistent{certified}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
